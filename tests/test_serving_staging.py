"""Staging parity suite: prefill staging through the command queue.

The tentpole invariant under test: a full serving round — prefill staging
(promotions), CoW splits, tail inits — drains as ONE fused launch, and the
fused staging path is byte-for-byte identical to the seed's ad-hoc
``_stage_legacy`` scatter path.  Three layers:

* engine-level: staged bytes promoted via ``OP_CROSS_POOL_COPY`` equal a
  direct scatter; the k_stage→k / v_stage→v pair for one destination block
  shares a flush (pool-aware hazard keys), while genuine staging↔KV
  RAW/WAW hazards still auto-flush;
* serving-level: random admit/fork/decode rounds through
  {``fused_staging=True``, ``fused_staging=False``} ServingEngines give
  bitwise-identical KV pools, identical greedy tokens, and exactly one
  bulk-movement launch per fused round (launch-count hook);
* mesh (subprocess, 8 host devices): the sharded-batch serving tables —
  ``batch_groups=2`` local share-mask columns — decode the same greedy
  tokens as the single-device engine;
* dedup-on-admit: fingerprint-matched prompt pages collapse onto shared
  CoW blocks at admission (identical prompts across tenants), shrinking
  resident KV while greedy tokens stay bitwise-equal to a dedup-off twin
  at <= 1 launch/round — first divergent append CoW-splits the shared
  tail (CPU and mesh legs).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _meshproc import run_device_subprocess
from repro.core import OutOfBlocks, RowCloneEngine, SubarrayAllocator
from repro.kernels import fused_dispatch as fd


# ---------------------------------------------------------------------------
# engine-level staging semantics
# ---------------------------------------------------------------------------

def _mk_staged_engine(nblk=32, seed=0):
    alloc = SubarrayAllocator(nblk, 4, reserved_zero_per_slab=1)
    shape = (nblk, 4, 8)
    pools = {
        "k": jax.random.normal(jax.random.key(seed), shape),
        "v": jax.random.normal(jax.random.key(seed + 1), shape),
        "k_stage": jax.random.normal(jax.random.key(seed + 2), shape),
        "v_stage": jax.random.normal(jax.random.key(seed + 3), shape),
    }
    return RowCloneEngine(pools, alloc, max_requests=64,
                          staging={"k_stage": "k", "v_stage": "v"})


def test_promotion_pair_shares_one_flush():
    """k_stage→k and v_stage→v of the SAME destination block are distinct
    (pool, block) writes, not a WAW hazard: the whole promotion is one
    launch, and both primary pools hold the staged bytes."""
    eng = _mk_staged_engine()
    want_k = np.asarray(eng.pools["k_stage"])
    want_v = np.asarray(eng.pools["v_stage"])
    slots = eng.stage_blocks(3)
    with fd_hook() as events:
        eng.promote_staged([(s, 10 + i) for i, s in enumerate(slots)])
    assert [m for _, _, m in events] == ["fused"], events
    assert eng.queue.stats.hazard_flushes == 0
    for i, s in enumerate(slots):
        np.testing.assert_array_equal(np.asarray(eng.pools["k"][10 + i]),
                                      want_k[s])
        np.testing.assert_array_equal(np.asarray(eng.pools["v"][10 + i]),
                                      want_v[s])
    # promoted slots reclaimed by the flush
    assert all(s in eng._stage_free for s in slots)
    assert eng.stats.stage_promotions == 3


def test_staging_kv_hazards_still_autoflush():
    """Genuine cross-address-space hazards serialize: a plain copy whose
    source is a pending promotion DESTINATION (RAW), or whose destination
    is one (WAW), forces a flush; an unrelated block does not."""
    # RAW: promote s->7, then memcopy (7, 9) reads pending dst 7
    eng = _mk_staged_engine(seed=5)
    staged = np.asarray(eng.pools["k_stage"])
    (s,) = eng.stage_blocks(1)
    with eng.batch():
        eng.promote_staged([(s, 7)])
        eng.memcopy([(7, 9)])
    assert eng.queue.stats.hazard_flushes == 1
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][9]), staged[s])

    # WAW: promote s->7, then memcopy (3, 7) rewrites pending dst 7
    eng2 = _mk_staged_engine(seed=6)
    eng2.alloc.mark_written([3])
    want3 = np.asarray(eng2.pools["k"][3])
    (s2,) = eng2.stage_blocks(1)
    with eng2.batch():
        eng2.promote_staged([(s2, 7)])
        eng2.memcopy([(3, 7)])
    assert eng2.queue.stats.hazard_flushes == 1
    np.testing.assert_array_equal(np.asarray(eng2.pools["k"][7]), want3)

    # no hazard: plain movement on blocks unrelated to the promotion's
    # (pool, block) keys rides the same single launch
    eng3 = _mk_staged_engine(seed=7)
    eng3.alloc.mark_written([3])
    (s3,) = eng3.stage_blocks(1)
    with fd_hook() as events, eng3.batch():
        eng3.promote_staged([(s3, 7)])
        eng3.memcopy([(3, 9)])
        eng3.materialize_zeros([11])
    assert eng3.queue.stats.hazard_flushes == 0
    assert [m for _, _, m in events] == ["fused"], events


def test_plain_ops_never_touch_staging_pools():
    """memcopy/meminit move blocks in PRIMARY pools only: staged bytes
    parked at the same numeric block id survive a plain copy and a zero
    init on every dispatch path."""
    for use_fused in (True, False):
        eng = _mk_staged_engine(seed=9)
        eng.use_fused = use_fused
        stage_before = {n: np.asarray(eng.pools[n])
                        for n in ("k_stage", "v_stage")}
        eng.alloc.mark_written([2])
        with eng.batch():
            eng.memcopy([(2, 5)])
            eng.materialize_zeros([6])
        for n, want in stage_before.items():
            np.testing.assert_array_equal(np.asarray(eng.pools[n]), want,
                                          err_msg=f"{n} fused={use_fused}")
        np.testing.assert_array_equal(np.asarray(eng.pools["k"][6]),
                                      np.zeros((4, 8), np.float32))


def test_stage_slot_exhaustion_flushes_then_raises():
    """stage_blocks reclaims in-flight slots by draining the queue; a
    request beyond pool capacity fails loudly."""
    eng = _mk_staged_engine()
    eng.deferred = True                     # serving-style open queue
    slots = eng.stage_blocks(30)
    eng.promote_staged([(s, i) for i, s in enumerate(slots[:8])])
    # 2 free + 8 in flight: requesting 5 must flush and succeed
    more = eng.stage_blocks(5)
    assert len(more) == 5
    assert eng.queue.stats.flushes >= 1
    with pytest.raises(RuntimeError):
        eng.stage_blocks(eng.num_blocks + 1)


def test_retire_promotions_cancels_queued_rows():
    """Sequence-lifecycle primitive behind ServingEngine.free: a queued
    promotion retires (rows leave the queue WITHOUT dispatching, slots
    rejoin the ring, no bytes move); one that already drained is simply
    not found."""
    eng = _mk_staged_engine(seed=11)
    eng.deferred = True                 # serving-style open queue
    want_k = np.asarray(eng.pools["k"])
    slots = eng.stage_blocks(2)
    pairs = list(zip(slots, [5, 6]))
    eng.promote_staged(pairs)
    assert len(eng.queue) == 4          # one k row + one v row per pair
    assert eng.retire_promotions(pairs) == 4
    assert len(eng.queue) == 0
    assert all(s in eng._stage_free for s in slots)
    assert eng.stats.retired_promotions == 4
    with fd_hook() as events:
        eng.flush()
    assert events == []                 # nothing left to dispatch
    np.testing.assert_array_equal(np.asarray(eng.pools["k"]), want_k)
    # a promotion whose flush already landed retires as a no-op
    (s2,) = eng.stage_blocks(1)
    eng.promote_staged([(s2, 8)])
    eng.flush()
    assert eng.retire_promotions([(s2, 8)]) == 0


def test_demote_resume_roundtrip_moves_bytes():
    """Preemption primitives: demote_to_spill parks a block's CURRENT
    bytes in one spill slot per pool pair (k→k_spill and v→v_spill travel
    together); promote_spilled lands them back in a fresh primary block
    bitwise, and the slots return to the demotion free list."""
    from repro.models.paged import make_serving_pools
    L, nblk, page = 2, 16, 2
    pools, group = make_serving_pools(L, nblk, page, 2, 4, jnp.float32,
                                      staging=True, stage_nblk=4,
                                      ckpt_nblk=4)
    alloc = SubarrayAllocator(nblk, 4, reserved_zero_per_slab=1)
    eng = RowCloneEngine(pools, alloc, block_axis=1, group=group)
    eng.enable_demotion(range(4))
    blocks = alloc.alloc(2)
    idx = np.asarray(blocks)
    for i, n in enumerate(("k", "v")):
        eng.pools[n] = eng.pools[n].at[:, idx].set(
            jax.random.normal(jax.random.key(i), (L, 2, page, 2, 4)))
    # the writes above are out of band of the allocator's ZI metadata —
    # exactly the decode-jit situation demote callers must mark_written
    alloc.mark_written(blocks)
    want = {n: np.asarray(eng.pools[n][:, idx]) for n in ("k", "v")}
    slots = eng.demote_to_spill(blocks)
    sidx = np.asarray(slots)
    assert eng.spill_slots_free == eng.spill_capacity - 2
    for n in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(eng.pools[n + "_spill"][:, sidx]), want[n])
    alloc.free(blocks)                  # the victim's blocks re-issue
    fresh = alloc.alloc(2)
    eng.promote_spilled(list(zip(slots, fresh)))
    for n in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(eng.pools[n][:, np.asarray(fresh)]), want[n])
    assert eng.stats.demotions == 2 and eng.stats.spill_promotions == 2
    # drained resume promotions recycle their slots (source-hazard
    # lifetime, same as staging); release is idempotent on top
    assert eng.spill_slots_free == eng.spill_capacity
    eng.release_spill_slots(slots)
    assert eng.spill_slots_free == eng.spill_capacity


def test_alloc_rollback_on_group_exhaustion():
    """A partial grab rolls back when the allowed slabs run out: group
    exhaustion is routine for sharded-batch serving, and leaked blocks
    would permanently shrink the group's capacity."""
    alloc = SubarrayAllocator(32, 4, reserved_zero_per_slab=1)
    free_before = alloc.free_in_slab(0) + alloc.free_in_slab(1)
    allocs_before = alloc.stats.allocs
    with pytest.raises(OutOfBlocks):
        alloc.alloc(free_before + 1, allowed_slabs=[0, 1])
    assert alloc.free_in_slab(0) + alloc.free_in_slab(1) == free_before
    assert alloc.stats.allocs == allocs_before
    assert not alloc.refcount[[b for s in (0, 1)
                               for b in range(s * 8, s * 8 + 8)
                               if b not in alloc.zero_rows]].any()


def fd_hook():
    class _Hook:
        def __enter__(self):
            self.events = []
            self._fn = lambda n, p, m: self.events.append((n, p, m))
            fd.add_launch_hook(self._fn)
            return self.events

        def __exit__(self, *exc):
            fd.remove_launch_hook(self._fn)
    return _Hook()


# ---------------------------------------------------------------------------
# serving-level parity: fused staging vs the seed _stage_legacy path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    from repro.configs import get_config
    from repro.models import build_model, split_params
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    return cfg, params


def _random_rounds(cfg, params, seed, n_rounds=5):
    """Drive fused and seed ServingEngines through identical random rounds.
    Returns (fused, legacy, per-round launch counts for the fused path)."""
    from repro.launch.serve import ServingEngine
    fused = ServingEngine(cfg, params, max_seqs=8)
    legacy = ServingEngine(cfg, params, max_seqs=8, fused_staging=False)
    rng = random.Random(seed)
    prng = np.random.default_rng(seed)
    sids: list = []
    fused_round_launches = []
    for rnd in range(n_rounds):
        plan = []
        if rnd == 0 or (rng.random() < 0.7 and len(sids) < 5):
            plan.append(("admit", prng.integers(
                2, cfg.vocab_size, size=rng.choice([9, 16, 24])).astype(
                    np.int32)))
        # fork only sequences admitted in EARLIER rounds: forking inside
        # the admission round reads a pending promotion dst and would
        # (correctly) hazard-flush into a second launch
        if sids and rng.random() < 0.4:
            plan.append(("fork", rng.choice(sids)))
        with fd_hook() as ev:
            for op, arg in plan:
                if op == "admit":
                    sids.append(fused.add_request(arg.copy()))
                else:
                    fused.fork(arg, 1)
            fused.decode_round()
        fused_round_launches.append([m for _, _, m in ev])
        for op, arg in plan:
            if op == "admit":
                legacy.add_request(arg.copy())
            else:
                legacy.fork(arg, 1)
        legacy.decode_round()
    return fused, legacy, fused_round_launches


@pytest.mark.slow
def test_serving_rounds_bitwise_parity_one_launch(served):
    """Random admit/fork/decode rounds: fused-staging pools == seed-staging
    pools bitwise, identical greedy tokens, and every fused round is
    exactly ONE bulk-movement launch (no legacy_stage dispatches)."""
    cfg, params = served
    for seed in (0, 1):
        fused, legacy, rounds = _random_rounds(cfg, params, seed)
        for name in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(fused.engine.pools[name]),
                np.asarray(legacy.engine.pools[name]),
                err_msg=f"pool {name} seed={seed}")
        assert fused.tokens == legacy.tokens
        for rnd, mechs in enumerate(rounds):
            assert all(m == "fused" for m in mechs), (seed, rnd, mechs)
            assert len(mechs) <= 1, (seed, rnd, mechs)
        # every admission staged through the queue, none through _stage_legacy
        assert fused.engine.stats.stage_promotions > 0
        assert legacy.engine.stats.stage_promotions == 0


def test_admission_round_is_one_launch(served):
    """The acceptance invariant, pinned: admit + decode = ONE fused launch
    covering the staged promotion (and the round's inits)."""
    cfg, params = served
    from repro.launch.serve import ServingEngine
    eng = ServingEngine(cfg, params, max_seqs=8)
    prompt = np.arange(2, 26, dtype=np.int32)
    with fd_hook() as ev:
        eng.add_request(prompt)
        eng.decode_round()
    assert [m for _, _, m in ev] == ["fused"], ev
    assert eng.engine.stats.stage_promotions == len(
        eng.cache.blocks_of(sorted(eng.cache.seqs)[0]))


# ---------------------------------------------------------------------------
# staging ring: per-pool nblk halves serving memory at bitwise parity
# ---------------------------------------------------------------------------

def _drive_rounds(eng, cfg, seed, n_rounds=5, hook_events=None):
    """Deterministic admit/fork/decode rounds (same plan for any engine
    built from the same seed)."""
    rng = random.Random(seed)
    prng = np.random.default_rng(seed)
    sids: list = []
    for rnd in range(n_rounds):
        plan = []
        if rnd == 0 or (rng.random() < 0.7 and len(sids) < 5):
            plan.append(("admit", prng.integers(
                2, cfg.vocab_size, size=rng.choice([9, 16, 24])).astype(
                    np.int32)))
        if sids and rng.random() < 0.4:
            plan.append(("fork", rng.choice(sids)))
        with fd_hook() as ev:
            for op, arg in plan:
                if op == "admit":
                    sids.append(eng.add_request(arg.copy()))
                else:
                    eng.fork(arg, 1)
            eng.decode_round()
        if hook_events is not None:
            hook_events.append([m for _, _, m in ev])
    return sids


@pytest.mark.slow
def test_staging_ring_halves_memory_bitwise_tokens(served):
    """The acceptance scenario, single-device leg: a serving engine whose
    staging pools are a RING (max_admit_pages slots, recycled every
    flush) instead of full-size KV twins must decode bitwise-identical
    greedy tokens at one fused launch per round, with >= 1.8x lower
    resident pool bytes."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    twin = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                         max_admit_pages=ServingEngine.FULL_TWIN)
    ring = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                         max_admit_pages=8)
    assert ring.engine.stage_capacity == 8
    assert ring.engine.stage_capacity < ring.engine.num_blocks
    ring_rounds: list = []
    _drive_rounds(twin, cfg, seed=3)
    _drive_rounds(ring, cfg, seed=3, hook_events=ring_rounds)
    assert twin.tokens == ring.tokens
    for name in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(twin.engine.pools[name]),
            np.asarray(ring.engine.pools[name]), err_msg=f"pool {name}")
    for rnd, mechs in enumerate(ring_rounds):
        assert all(m == "fused" for m in mechs), (rnd, mechs)
        assert len(mechs) <= 1, (rnd, mechs)
    reduction = (twin.engine.pool_bytes_resident()
                 / ring.engine.pool_bytes_resident())
    assert reduction >= 1.8, reduction


def _burst_rounds(eng, cfg, n_rounds=2, admits_per_round=3,
                  prompt_len=24):
    """Admit ``admits_per_round`` prompts then decode, per round.  Returns
    the per-round bulk-movement mechanism lists (launch hook)."""
    prng = np.random.default_rng(11)
    rounds = []
    for _ in range(n_rounds):
        with fd_hook() as ev:
            for _ in range(admits_per_round):
                eng.add_request(prng.integers(
                    2, cfg.vocab_size, size=prompt_len).astype(np.int32))
            eng.decode_round()
        rounds.append([m for _, _, m in ev])
    return rounds


@pytest.mark.slow
def test_burst_admissions_double_buffered_one_launch(served):
    """The tentpole serving invariant: admissions bursting past the
    ring's nominal capacity (3 staged pages/round vs a 2-slot ring) land
    in the shadow half of a double-buffered ring and the round still
    drains as ONE fused launch — while the single-buffered ring pays an
    early-flush launch — with greedy tokens bitwise-identical across
    double-buffered, single-buffered, and seed staging."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    double = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                           max_admit_pages=2, double_buffer=True)
    single = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                           max_admit_pages=2)
    seed = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                         fused_staging=False)
    assert double.ring_capacity == 2
    assert double.engine.stage_capacity == 4    # live + shadow halves
    assert single.engine.stage_capacity == 2
    r_double = _burst_rounds(double, cfg)
    r_single = _burst_rounds(single, cfg)
    _burst_rounds(seed, cfg)
    assert double.tokens == single.tokens == seed.tokens
    for name in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(double.engine.pools[name]),
            np.asarray(single.engine.pools[name]), err_msg=f"pool {name}")
    for rnd, mechs in enumerate(r_double):
        assert mechs == ["fused"], (rnd, mechs)     # 1.0 launches/round
    # the single-buffered ring pays the early flush under the same burst
    assert any(len(mechs) > 1 for mechs in r_single), r_single
    # the round's FlushTicket carries the launch accounting
    t = double.last_ticket
    assert t is not None and t.stream == "serve" and t.launches == 1


def test_burst_ticket_and_slot_lifetime(served):
    """Source-hazard slot lifetime, end to end: while a burst round's
    promotions are queued on the serve stream, their staging slots hold
    pending READS and stay out of the free list; the round flush (one
    launch) retires the reads and recycles every slot."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    eng = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                        max_admit_pages=2, double_buffer=True)
    prng = np.random.default_rng(5)
    sidx = [eng.engine.group.index(n) for n in eng.engine.staging]
    for i in range(3):
        eng.add_request(prng.integers(2, cfg.vocab_size, size=24)
                        .astype(np.int32))
        inflight = list(eng.engine._stage_inflight)
        assert len(inflight) == i + 1
        assert all(eng.stream.queue.has_pending_read((p, s))
                   for s in inflight for p in sidx)
    eng.decode_round()
    assert eng.engine._stage_inflight == []
    assert len(eng.engine._stage_free) == eng.engine.stage_capacity
    assert eng.last_ticket.launches == 1


def test_ring_exhaustion_flushes_and_recycles(served):
    """Admissions beyond the ring's capacity inside one round force an
    early drain (promotions flush, slots recycle) instead of failing —
    the ring only ever needs to hold the pages between two flushes."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    eng = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                        max_admit_pages=1)
    prng = np.random.default_rng(0)
    for _ in range(3):      # each admission needs the ring's only slot
        eng.add_request(prng.integers(2, cfg.vocab_size, size=9)
                        .astype(np.int32))
    eng.decode_round()
    assert eng.engine.stats.stage_promotions == 3
    assert len(eng.engine._stage_free) == eng.engine.stage_capacity


# ---------------------------------------------------------------------------
# mesh leg: sharded-batch serving tables (local share-mask columns)
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# dedup-on-admit: fingerprint-matched prompt pages share CoW blocks
# ---------------------------------------------------------------------------

def test_dedup_identical_prompts_share_blocks_bitwise_tokens(served):
    """Two tenants admitting the SAME prompt: the dupe's pages (full AND
    the partial tail) collapse onto the donor's blocks, resident KV
    shrinks, every round stays one fused launch, greedy tokens are
    bitwise-equal to a dedup-off twin, and the first append CoW-splits
    the shared tail while the full prompt pages stay shared."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    on = ServingEngine(cfg, params, max_seqs=8, dedup_admit=True)
    off = ServingEngine(cfg, params, max_seqs=8)
    page = on.cache.page
    rng = np.random.default_rng(5)
    prompt = rng.integers(2, cfg.vocab_size,
                          size=2 * page + page // 2).astype(np.int32)
    a = on.add_request(prompt.copy())
    b = on.add_request(prompt.copy())
    # the dupe runs on the donor's blocks — all three pages, tail included
    assert on.cache.blocks_of(a) == on.cache.blocks_of(b)
    assert on.dedup_hits == 1 and on.dedup_pages_shared == 3
    assert all(on.engine.alloc.is_shared(blk)
               for blk in on.cache.blocks_of(a))
    for p in (prompt.copy(), prompt.copy()):
        off.add_request(p)
    assert on.kv_bytes_live() < off.kv_bytes_live()
    rounds = []
    for _ in range(3):
        with fd_hook() as ev:
            on.decode_round()
        rounds.append([m for _, _, m in ev])
        assert on.last_ticket.launches <= 1   # the decode ticket itself
        off.decode_round()
    assert on.tokens == off.tokens       # bitwise-equal greedy tokens
    for rnd, mechs in enumerate(rounds):
        assert all(m == "fused" for m in mechs), (rnd, mechs)
        # round 0 carries one extra flush: the shared tail's CoW split
        assert len(mechs) <= (2 if rnd == 0 else 1), (rnd, mechs)
    # first divergent append split the shared tail; full pages still shared
    ba, bb = on.cache.blocks_of(a), on.cache.blocks_of(b)
    assert ba[:2] == bb[:2]
    assert ba[2] != bb[2]
    assert all(on.engine.alloc.is_shared(blk) for blk in ba[:2])


def test_dedup_shares_only_common_prefix_pages(served):
    """Prompts that agree on the first pages but diverge later share
    exactly the common-prefix pages — the chained fingerprint makes a
    same-content page at a different history a MISS, never a false
    share."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    on = ServingEngine(cfg, params, max_seqs=8, dedup_admit=True)
    page = on.cache.page
    rng = np.random.default_rng(9)
    p1 = rng.integers(2, cfg.vocab_size, size=3 * page).astype(np.int32)
    p2 = p1.copy()
    p2[-1] = 2 + (int(p2[-1]) - 1) % (cfg.vocab_size - 2)  # last tok differs
    a = on.add_request(p1)
    b = on.add_request(p2)
    ba, bb = on.cache.blocks_of(a), on.cache.blocks_of(b)
    assert ba[:2] == bb[:2]              # common prefix shared
    assert ba[2] != bb[2]                # divergent page NOT shared
    assert on.dedup_pages_shared == 2
    # same bytes, different position/history: page 0's content re-admitted
    # as page 1 of a third prompt must not match (chained fp)
    p3 = np.concatenate([p1[:page], p1[:page], p1[:page]])
    c = on.add_request(p3)
    bc = on.cache.blocks_of(c)
    assert bc[0] == ba[0]                # page 0 matches the donor
    assert bc[1] not in ba               # page 1 is a fresh block
    assert on.dedup_pages_shared == 3


def test_dedup_registry_drops_with_registering_sequence(served):
    """Registry entries die with the sequence that registered them: after
    the donor frees, a re-admission gets FRESH blocks (no stale donor),
    then becomes the new donor for later dupes."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    on = ServingEngine(cfg, params, max_seqs=8, dedup_admit=True)
    page = on.cache.page
    rng = np.random.default_rng(13)
    prompt = rng.integers(2, cfg.vocab_size, size=2 * page).astype(np.int32)
    a = on.add_request(prompt.copy())
    on.free(a)
    b = on.add_request(prompt.copy())    # registry emptied: a clean miss
    assert on.dedup_hits == 0
    c = on.add_request(prompt.copy())    # b is the new donor
    assert on.dedup_hits == 1
    assert on.cache.blocks_of(b) == on.cache.blocks_of(c)


MESH_SERVE_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.launch.serve import ServingEngine
from repro.models import build_model, split_params

results = {}
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_config("llama3.2-3b").reduced()
model = build_model(cfg)
params, _ = split_params(model.init_params(jax.random.key(0)))

ref = ServingEngine(cfg, params, max_seqs=8)
srv = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                    max_blocks_per_seq=8, num_slabs=4)
results["batch_groups"] = srv.cache.batch_groups
results["mask_cols"] = int(srv.cache.device_tables()[1].shape[1])

rng = np.random.default_rng(3)
sids = []
for i in range(3):
    p = rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
    sids.append((ref.add_request(p.copy()), srv.add_request(p.copy())))
ref.decode_round()
srv.decode_round()
# fork an older sequence, keep decoding
rk, sk = sids[0]
ref.fork(rk, 1)
srv.fork(sk, 1)
for _ in range(3):
    ref.decode_round()
    srv.decode_round()
results["tokens_match"] = bool(all(
    ref.tokens[r] == srv.tokens[s] for r, s in sids))
# the mesh engine's sequences really are group-pinned
groups = {sid: seq.group for sid, seq in srv.cache.seqs.items()}
results["groups_used"] = sorted(set(groups.values()))
results["placement_ok"] = bool(all(
    srv.cache.group_of_block(b) == seq.group
    for seq in srv.cache.seqs.values() for b in seq.blocks))

# staging-ring acceptance, mesh leg: a ring of 8 slots (vs 128-block KV
# pools) decodes the same greedy tokens as the full twin, one collective
# launch per round, >= 1.8x lower resident pool bytes
from repro.kernels import fused_dispatch as fd
twin = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                     max_blocks_per_seq=16, num_slabs=4,
                     max_admit_pages=ServingEngine.FULL_TWIN)
ring = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                     max_blocks_per_seq=16, num_slabs=4, max_admit_pages=8)
rng2 = np.random.default_rng(7)
ring_mechs = []
hook = lambda n, p, m: ring_mechs.append(m)
for i in range(3):
    p = rng2.integers(2, cfg.vocab_size, size=16).astype(np.int32)
    tw = twin.add_request(p.copy())
    fd.add_launch_hook(hook)
    rg = ring.add_request(p.copy())
    fd.remove_launch_hook(hook)
    twin.decode_round()
    fd.add_launch_hook(hook)
    n0 = len(ring_mechs)
    ring.decode_round()
    fd.remove_launch_hook(hook)
    assert len(ring_mechs) - n0 <= 1, ring_mechs
twin.fork(tw, 1)
ring.fork(rg, 1)
for _ in range(3):
    twin.decode_round()
    ring.decode_round()
results["ring_capacity"] = ring.engine.stage_capacity
results["ring_kv_nblk"] = ring.engine.num_blocks
results["ring_tokens_match"] = bool(all(
    twin.tokens[s] == ring.tokens[s] for s in twin.tokens))
results["ring_mechs_fused"] = bool(all(
    m == "fused_mesh" for m in ring_mechs))
results["ring_reduction"] = float(
    twin.engine.pool_bytes_resident() / ring.engine.pool_bytes_resident())

# burst-admission acceptance, mesh leg: 3 staged pages/round vs a 2-slot
# double-buffered ring — every round must stay ONE collective launch with
# tokens identical to a single-device double-buffered engine
burst_cpu = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                          max_admit_pages=2, double_buffer=True)
burst = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                      max_blocks_per_seq=16, num_slabs=4,
                      max_admit_pages=2, double_buffer=True)
rng3 = np.random.default_rng(11)
burst_rounds = []
for _ in range(2):
    prompts = [rng3.integers(2, cfg.vocab_size, size=24).astype(np.int32)
               for _ in range(3)]
    for p in prompts:
        burst_cpu.add_request(p.copy())
    burst_cpu.decode_round()
    mechs = []
    hook2 = lambda n, p, m: mechs.append(m)
    fd.add_launch_hook(hook2)
    for p in prompts:
        burst.add_request(p.copy())
    burst.decode_round()
    fd.remove_launch_hook(hook2)
    burst_rounds.append(mechs)
results["burst_mesh_rounds"] = burst_rounds
results["burst_one_launch"] = bool(all(
    r == ["fused_mesh"] for r in burst_rounds))
results["burst_tokens_match"] = bool(
    burst.tokens == burst_cpu.tokens)

# replicated staging ring: 3 slots don't divide the 8 device shards, so
# the ring is held whole on every device (PoolSpec.sharding == ()) and
# promotions drain collectively without rounding the ring up
repl_cpu = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                         max_admit_pages=3)
repl = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                     max_blocks_per_seq=16, num_slabs=4, max_admit_pages=3)
results["repl_capacity"] = repl.engine.stage_capacity
results["repl_sharding_hint"] = list(
    repl.engine.group["k_stage"].sharding or [])
repl_mechs = []
hook3 = lambda n, p, m: repl_mechs.append(m)
rng4 = np.random.default_rng(13)
for _ in range(3):
    p = rng4.integers(2, cfg.vocab_size, size=24).astype(np.int32)
    repl_cpu.add_request(p.copy())
    repl_cpu.decode_round()
    n0 = len(repl_mechs)
    fd.add_launch_hook(hook3)
    repl.add_request(p.copy())
    repl.decode_round()
    fd.remove_launch_hook(hook3)
    assert len(repl_mechs) - n0 <= 1, repl_mechs
results["repl_tokens_match"] = bool(repl.tokens == repl_cpu.tokens)
results["repl_mechs_fused"] = bool(all(
    m == "fused_mesh" for m in repl_mechs))

# dedup-on-admit, mesh leg: identical prompts across tenants collapse
# onto shared blocks WITHIN a batch group (group-pinned sharing only),
# greedy tokens match the dedup-off twin, rounds stay one collective
# launch, and block placement stays group-sound after CoW splits
ded_off = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                        max_blocks_per_seq=8, num_slabs=4)
ded_on = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                       max_blocks_per_seq=8, num_slabs=4,
                       dedup_admit=True)
rng5 = np.random.default_rng(17)
page = ded_on.cache.page
canon = [rng5.integers(2, cfg.vocab_size,
                       size=2 * page + page // 2).astype(np.int32)
         for _ in range(2)]
sid_pairs = [(ded_off.add_request(canon[t % 2].copy()),
              ded_on.add_request(canon[t % 2].copy()))
             for t in range(4)]
ded_mechs = []
hook5 = lambda n, p, m: ded_mechs.append(m)
for rnd in range(3):
    ded_off.decode_round()
    n0 = len(ded_mechs)
    fd.add_launch_hook(hook5)
    ded_on.decode_round()
    fd.remove_launch_hook(hook5)
    # round 0 carries the shared-tail CoW split flushes on top of the
    # decode ticket; later rounds must be a single collective launch
    assert ded_on.last_ticket.launches <= 1, ded_mechs
    assert len(ded_mechs) - n0 <= (3 if rnd == 0 else 1), ded_mechs
results["dedup_tokens_match"] = bool(all(
    ded_off.tokens[a] == ded_on.tokens[b] for a, b in sid_pairs))
results["dedup_mechs_fused"] = bool(all(
    m == "fused_mesh" for m in ded_mechs))
results["dedup_hits"] = int(ded_on.dedup_hits)
results["dedup_kv_on"] = int(ded_on.kv_bytes_live())
results["dedup_kv_off"] = int(ded_off.kv_bytes_live())
results["dedup_group_ok"] = bool(all(
    ded_on.cache.group_of_block(b) == seq.group
    for seq in ded_on.cache.seqs.values() for b in seq.blocks))
print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.slow
@pytest.mark.mesh
def test_sharded_batch_serving_decodes_like_single_device(tmp_path):
    """The PR-2-era restriction is gone: under a (2, 4) mesh the decode
    batch shards over the data axis (batch_groups=2, LOCAL share-mask
    columns, group-pinned block placement) and greedy decode produces the
    single-device engine's tokens exactly."""
    res = run_device_subprocess(MESH_SERVE_CHILD, tmp_path=tmp_path)
    assert res["batch_groups"] == 2, res
    assert res["mask_cols"] == 4, res          # max_seqs 8 / 2 groups
    assert res["tokens_match"], res
    assert res["placement_ok"], res
    assert res["groups_used"] == [0, 1], res
    # staging-ring acceptance on the mesh: 8-slot ring vs 128-block KV,
    # bitwise greedy tokens, collective launches only, >= 1.8x memory win
    assert res["ring_capacity"] == 8 < res["ring_kv_nblk"], res
    assert res["ring_tokens_match"], res
    assert res["ring_mechs_fused"], res
    assert res["ring_reduction"] >= 1.8, res
    # burst-admission acceptance on the mesh: 3 staged pages/round into a
    # 2-slot double-buffered ring, still ONE collective launch per round,
    # tokens identical to the single-device double-buffered engine
    assert res["burst_one_launch"], res
    assert res["burst_tokens_match"], res
    # replicated staging ring (3 slots, 8 shards): sharding hint (),
    # one collective launch per round, tokens match single-device
    assert res["repl_capacity"] == 3, res
    assert res["repl_sharding_hint"] == [], res
    assert res["repl_tokens_match"], res
    assert res["repl_mechs_fused"], res
    # dedup-on-admit on the mesh: identical prompts share group-pinned
    # blocks, greedy tokens bitwise-match the dedup-off twin, rounds stay
    # one collective launch, and every block stays in its sequence's group
    assert res["dedup_tokens_match"], res
    assert res["dedup_mechs_fused"], res
    assert res["dedup_hits"] >= 1, res
    assert res["dedup_kv_on"] < res["dedup_kv_off"], res
    assert res["dedup_group_ok"], res
