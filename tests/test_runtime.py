"""Fault tolerance: restart-on-failure, determinism of replay, straggler
detection, elastic re-mesh planning."""
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.launch.train import train_loop
from repro.runtime import (HeartbeatLedger, NodeFailure, RestartPolicy,
                           plan_remesh, run_with_restarts)


def test_train_restart_reproduces_loss_trajectory(tmp_path):
    """Crash at step 15, restart from checkpoint 10 → identical losses."""
    arch = "llama3.2-3b"
    # uninterrupted run
    _, ref_losses = train_loop(arch, steps=20, batch=2, seq_len=64,
                               smoke=True, ckpt_dir=None)
    # interrupted run
    ckpt_dir = str(tmp_path / "ckpt")
    with pytest.raises(NodeFailure):
        train_loop(arch, steps=20, batch=2, seq_len=64, smoke=True,
                   ckpt_dir=ckpt_dir, inject_failure_at=15,
                   checkpoint_every=10)
    ckpt = CheckpointManager(ckpt_dir)
    assert ckpt.latest_step() == 10
    _, resumed = train_loop(arch, steps=20, batch=2, seq_len=64, smoke=True,
                            ckpt_dir=ckpt_dir, checkpoint_every=10)
    # steps 10..19 must match the uninterrupted run exactly (determinism)
    np.testing.assert_allclose(resumed, ref_losses[10:], rtol=1e-5)


def test_run_with_restarts_driver(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    calls = {"n": 0}

    def loop(start, state):
        calls["n"] += 1
        if calls["n"] < 3:
            ckpt.save(calls["n"] * 10, {"x": np.float32(calls["n"])})
            raise NodeFailure("boom")
        return ("done", start)

    result = run_with_restarts(loop, {"x": np.float32(0)}, ckpt,
                               RestartPolicy(max_restarts=5))
    assert result[0] == "done"
    assert result[1] == 20          # resumed from latest checkpoint step
    assert calls["n"] == 3


def test_run_with_restarts_gives_up():
    ckpt = CheckpointManager("/tmp/_nonexistent_ckpt_dir_test", keep=1)

    def loop(start, state):
        raise NodeFailure("always")

    with pytest.raises(RuntimeError, match="restarts"):
        run_with_restarts(loop, {}, ckpt, RestartPolicy(max_restarts=2))


def test_straggler_detection(monkeypatch):
    # drive the ledger's clock explicitly: real sleeps made the warm-up
    # steps flake under load (a 2x scheduler hiccup IS a straggler)
    from repro.runtime import fault as fault_mod
    clock = {"t": 0.0}
    monkeypatch.setattr(fault_mod.obs_metrics, "now", lambda: clock["t"])
    ledger = HeartbeatLedger(window=20, threshold=2.0)
    for step in range(8):
        ledger.step_start()
        clock["t"] += 0.01
        assert ledger.step_end(step) is None
    ledger.step_start()
    clock["t"] += 0.08              # 8x median
    rep = ledger.step_end(99)
    assert rep is not None and rep.ratio > 2.0
    assert ledger.reports[-1].step == 99


def test_step_end_without_step_start_returns_none():
    # regression: step_end before any step_start used to TypeError on
    # the None start time; it must be a clean no-op
    ledger = HeartbeatLedger()
    assert ledger.step_end(0) is None
    assert ledger.times == []
    # and a start consumed by one end doesn't leak into a second end
    ledger.step_start()
    ledger.step_end(1)
    assert len(ledger.times) == 1
    assert ledger.step_end(2) is None
    assert len(ledger.times) == 1


def test_elastic_remesh_preserves_tp_and_global_batch():
    d = plan_remesh(n_devices=512, model_parallel=16, global_batch=256,
                    old_dp=32, multi_pod=True)
    assert d.mesh_shape == (2, 16, 16) and d.dp_size == 32
    assert d.microbatches == 1
    # lose one pod's worth: dp shrinks, microbatches compensate
    d2 = plan_remesh(n_devices=256, model_parallel=16, global_batch=256,
                     old_dp=32)
    assert d2.dp_size == 16
    assert d2.microbatches == 2      # 32/16
    with pytest.raises(ValueError):
        plan_remesh(n_devices=8, model_parallel=16, global_batch=256,
                    old_dp=32)


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint saved once restores under a different (1-device) 'mesh'
    via explicit shardings — the elastic path's data motion."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    ckpt.save(1, state)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, step = ckpt.restore(state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
