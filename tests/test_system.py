"""End-to-end behaviour tests for the whole system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train_loop


def test_training_reduces_loss():
    """A tiny dense model must actually learn on the synthetic stream (the
    pipeline's affine-successor structure is learnable)."""
    _, losses = train_loop("llama3.2-3b", steps=30, batch=4, seq_len=128,
                           smoke=True, learning_rate=3e-3)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)
    assert np.isfinite(losses).all()


def test_training_microbatch_equivalence():
    """microbatches=2 must track microbatches=1 (same global batch)."""
    _, l1 = train_loop("yi-6b", steps=8, batch=4, seq_len=64, smoke=True,
                       microbatches=1)
    _, l2 = train_loop("yi-6b", steps=8, batch=4, seq_len=64, smoke=True,
                       microbatches=2)
    np.testing.assert_allclose(l1, l2, rtol=2e-2)


def test_ssm_training_runs():
    _, losses = train_loop("mamba2-780m", steps=10, batch=2, seq_len=128,
                           smoke=True)
    assert np.isfinite(losses).all()


def test_moe_training_runs_and_balances():
    _, losses = train_loop("deepseek-moe-16b", steps=10, batch=2,
                           seq_len=64, smoke=True)
    assert np.isfinite(losses).all()
