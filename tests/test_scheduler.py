"""Traffic-layer suite: RequestScheduler rounds + sequence lifecycle.

Three layers, mirroring the serve_traffic benchmark gate:

* lifecycle regression — the free-before-flush bug: a sequence freed
  while its stage→KV promotion is still queued must RETIRE the promotion
  (rows leave the queues, staging slots recycle) instead of letting the
  stale copy land in blocks the allocator has re-issued.  The corruption
  leg re-allocates the freed blocks, writes a marker out of band, and
  asserts the marker survives the next flush — on the pre-fix engine the
  stale promotion clobbers it silently;
* scheduler semantics — continuous batching holds 1.0 bulk-movement
  launches per round under admission churn; priority preemption demotes
  a strictly-lower-priority victim, admits the waiter the NEXT round,
  resumes the victim later, and the preempted request's greedy tokens
  match a never-preempted run bitwise (the spill slots hold the real KV
  pages);
* mesh (subprocess, 8 host devices): the preempt→demote→resume parity
  leg under a (2, 4) mesh — demotion cross-pool copies ride the same
  collective launches as everything else.
"""
import jax
import numpy as np
import pytest

from _meshproc import run_device_subprocess
from repro.kernels import fused_dispatch as fd
from repro.kernels.fused_dispatch import OP_CROSS_POOL_COPY

PARITY_TOKENS = 8


def fd_hook():
    class _Hook:
        def __enter__(self):
            self.events = []
            self._fn = lambda n, p, m: self.events.append((n, p, m))
            fd.add_launch_hook(self._fn)
            return self.events

        def __exit__(self, *exc):
            fd.remove_launch_hook(self._fn)
    return _Hook()


@pytest.fixture(scope="module")
def served():
    from repro.configs import get_config
    from repro.models import build_model, split_params
    cfg = get_config("llama3.2-3b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    return cfg, params


# ---------------------------------------------------------------------------
# lifecycle: free before the round's flush
# ---------------------------------------------------------------------------

def test_free_before_flush_retires_promotion(served):
    """REGRESSION (fails on the pre-fix engine): freeing a sequence whose
    admission promotion has not flushed must retire the queued rows and
    recycle the staging slots — and the freed blocks, once re-issued,
    must never receive the dead sequence's staged bytes."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    eng = ServingEngine(cfg, params, max_seqs=8, max_blocks_per_seq=16,
                        max_admit_pages=4, double_buffer=True)
    prng = np.random.default_rng(11)
    prompt = prng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
    sid = eng.add_request(prompt)
    blocks = list(eng.cache.blocks_of(sid))
    assert any(op == OP_CROSS_POOL_COPY
               for op, _, _ in eng.stream.pending)   # promotion is queued
    eng.free(sid)
    # the queued promotion must be gone, not waiting to fire later
    assert not any(op == OP_CROSS_POOL_COPY
                   for op, _, _ in eng.stream.pending), \
        "stale stage->KV promotion left queued after free()"
    # its staging slots are back in the ring (pre-fix: leaked in flight)
    # the adaptive ring may have parked free slots above its clamp —
    # nothing leaked as long as free + parked covers the whole ring
    assert len(eng.engine._stage_free) + len(eng.engine._stage_parked) \
        == eng.engine.stage_capacity
    # and recovery bookkeeping no longer names the dead sequence
    assert sid not in eng._staged_sids

    # corruption leg: the allocator re-issues the freed blocks to a NEW
    # sequence whose bytes arrive out of band (exactly how decode writes
    # pool pages, invisible to the queues' hazard tracking).  A stale
    # promotion would overwrite them at the next flush.
    sid2 = eng.cache.new_sequence(
        prompt_len=len(prompt),
        prefer_slab=eng.engine.alloc.slab_of(blocks[0]))
    blocks2 = list(eng.cache.blocks_of(sid2))
    assert set(blocks2) & set(blocks), "allocator did not re-issue blocks"
    eng.engine.alloc.mark_written(blocks2)
    marker = 3.25
    idx = np.asarray(blocks2)
    for n in ("k", "v"):
        eng.engine.pools[n] = eng.engine.pools[n].at[:, idx].set(marker)
    eng.stream.flush()
    for n in ("k", "v"):
        got = np.asarray(eng.engine.pools[n][:, idx])
        assert np.all(got == marker), \
            f"{n}: stale promotion corrupted re-issued blocks"


@pytest.mark.slow
def test_free_drops_extra_host_state():
    """Non-dense host state (conv/ssm) keyed by sid must die with the
    sequence: under churn the per-request entries previously accumulated
    forever.  Hybrid prefill populates ``_extras``; free() must pop it
    (decode for this family goes through model.decode_step directly, so
    the test exercises admission + free only)."""
    from repro.configs import get_config
    from repro.launch.serve import ServingEngine
    from repro.models import build_model, split_params
    cfg = get_config("zamba2-2.7b").reduced()
    model = build_model(cfg)
    params, _ = split_params(model.init_params(jax.random.key(0)))
    eng = ServingEngine(cfg, params, max_seqs=4, max_blocks_per_seq=8)
    prng = np.random.default_rng(0)
    sids = [eng.add_request(prng.integers(2, cfg.vocab_size, size=8)
                            .astype(np.int32)) for _ in range(3)]
    assert all(s in eng._extras for s in sids)
    for s in sids:
        eng.free(s)
    assert eng._extras == {}
    assert eng.cache.seqs == {}


# ---------------------------------------------------------------------------
# scheduler: continuous batching, QoS lanes, preemption
# ---------------------------------------------------------------------------

def _sched_engine(cfg, params, **kw):
    from repro.launch.serve import ServingEngine
    base = dict(max_seqs=4, max_blocks_per_seq=8, num_slabs=2,
                max_admit_pages=8, double_buffer=True, spill_pages=8)
    base.update(kw)
    return ServingEngine(cfg, params, **base)


def test_continuous_batching_single_launch_and_reclaim(served):
    """Staggered admissions/retirements across two lanes: every round's
    bulk movement is at most ONE fused launch, and a fully drained
    scheduler returns every block, batch slot, staging slot, and spill
    slot."""
    from repro.launch.scheduler import RequestScheduler, TenantSpec
    cfg, params = served
    eng = _sched_engine(cfg, params)
    free0 = eng.engine.alloc.total_free()
    sched = RequestScheduler(eng, [TenantSpec("gold", 1),
                                   TenantSpec("free", 0)])
    prng = np.random.default_rng(3)
    plan = {0: [("free", 9), ("free", 16)], 1: [("gold", 9)],
            3: [("free", 24)]}
    r = 0
    while not sched.idle or r < 5:
        for tenant, plen in plan.get(r, []):
            sched.submit(tenant, prng.integers(2, cfg.vocab_size, size=plen)
                         .astype(np.int32), max_new_tokens=4)
        with fd_hook() as ev:
            rep = sched.step()
        assert rep.launches <= 1, (r, rep)
        assert all(m == "fused" for _, _, m in ev), ev
        r += 1
        assert r < 60, "scheduler failed to drain"
    assert all(q.state == "done" for q in sched.requests.values())
    assert all(len(q.tokens_out) == q.max_new_tokens
               for q in sched.requests.values())
    # everything reclaimed: sequences, pool blocks, staging + spill slots
    assert eng.cache.seqs == {}
    assert eng.engine.alloc.total_free() == free0
    # the adaptive ring may have parked free slots above its clamp —
    # nothing leaked as long as free + parked covers the whole ring
    assert len(eng.engine._stage_free) + len(eng.engine._stage_parked) \
        == eng.engine.stage_capacity
    assert eng.engine.spill_slots_free == eng.engine.spill_capacity


@pytest.mark.slow
def test_preempt_demote_resume_bitwise_parity(served):
    """A gold arrival on a full 2-slot engine demotes a free-tenant
    victim (OP_CROSS_POOL_COPY into the spill slots), admits the NEXT
    round, and the victim resumes later — with every request's greedy
    tokens bitwise-identical to a roomy engine that never preempts, at
    <= 1.0 launches/round throughout."""
    from repro.launch.scheduler import RequestScheduler, TenantSpec
    cfg, params = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(3)]
    tenants = [TenantSpec("gold", 2), TenantSpec("free", 0)]

    def drive(eng):
        sched = RequestScheduler(eng, tenants)
        rids = [sched.submit("free", prompts[0],
                             max_new_tokens=PARITY_TOKENS),
                sched.submit("free", prompts[1],
                             max_new_tokens=PARITY_TOKENS)]
        sched.step()
        sched.step()
        rids.append(sched.submit("gold", prompts[2],
                                 max_new_tokens=PARITY_TOKENS))
        sched.drain(max_rounds=120)
        return sched, rids

    roomy, roomy_rids = drive(_sched_engine(cfg, params, max_seqs=8,
                                            num_slabs=4, spill_pages=0))
    tight, tight_rids = drive(_sched_engine(cfg, params, max_seqs=2))

    assert sum(q.preemptions for q in roomy.requests.values()) == 0
    assert sum(q.preemptions for q in tight.requests.values()) > 0
    assert max(r.launches for r in tight.reports) <= 1
    # the gold waiter admits exactly one round after the demotion (the
    # victim's blocks come back at the demotion round's flush)
    gold_rid = tight_rids[2]
    demote_round = next(r.round_index for r in tight.reports if r.preempted)
    admit_round = next(r.round_index for r in tight.reports
                       if gold_rid in r.admitted)
    assert admit_round == demote_round + 1
    # the victim resumed and everyone finished
    assert any(r.resumed for r in tight.reports)
    assert all(q.state == "done" for q in tight.requests.values())
    # bitwise parity: preemption round-trips the real KV bytes
    assert [tight.requests[r].tokens_out for r in tight_rids] == \
        [roomy.requests[r].tokens_out for r in roomy_rids]


def test_cancel_in_every_state(served):
    """cancel() unwinds a request whether it is queued (never admitted),
    running (frees the live sequence — mid-round promotions retire), or
    preempted (releases the spill parking slots)."""
    from repro.launch.scheduler import RequestScheduler, TenantSpec
    cfg, params = served
    eng = _sched_engine(cfg, params, max_seqs=2)
    sched = RequestScheduler(eng, [TenantSpec("gold", 1),
                                   TenantSpec("free", 0)])
    prng = np.random.default_rng(7)
    mk = lambda n: prng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
    r_free = [sched.submit("free", mk(9), max_new_tokens=32),
              sched.submit("free", mk(9), max_new_tokens=32)]
    sched.step()
    sched.step()
    r_gold = sched.submit("gold", mk(9), max_new_tokens=4)
    sched.step()                        # demotes one free victim
    parked = next(r for r in r_free
                  if sched.requests[r].state == "preempted")
    running = next(r for r in r_free if r != parked)
    sched.cancel(parked)                # spill parking released
    assert sched.requests[parked].state == "cancelled"
    assert eng.engine.spill_slots_free == eng.engine.spill_capacity
    sched.cancel(running)               # live sequence freed
    r_q = sched.submit("free", mk(9), max_new_tokens=4)
    sched.cancel(r_q)                   # still queued: just dequeued
    assert sched.requests[r_q].state == "cancelled"
    sched.drain(max_rounds=60)          # gold still completes
    assert sched.requests[r_gold].state == "done"
    assert len(sched.requests[r_gold].tokens_out) == 4
    assert eng.cache.seqs == {}


def test_demote_while_staged_is_refused(served):
    """A sequence admitted THIS round (promotion still queued) cannot be
    demoted — the parked bytes would race the promotion.  The scheduler
    defers such victims a round; the engine enforces it."""
    from repro.launch.serve import ServingEngine
    cfg, params = served
    eng = _sched_engine(cfg, params)
    prng = np.random.default_rng(1)
    sid = eng.add_request(prng.integers(2, cfg.vocab_size, size=9)
                          .astype(np.int32))
    with pytest.raises(RuntimeError, match="not drained"):
        eng.demote(sid)
    eng.decode_round()
    eng.demote(sid)                     # next round it is fair game
    assert sid in eng.demoted
    eng.free(sid)                       # parked free releases the slots
    assert eng.engine.spill_slots_free == eng.engine.spill_capacity


# ---------------------------------------------------------------------------
# mesh leg: preempt -> demote -> resume parity under a (2, 4) device mesh
# ---------------------------------------------------------------------------

MESH_SCHED_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.launch.scheduler import RequestScheduler, TenantSpec
from repro.launch.serve import ServingEngine
from repro.models import build_model, split_params

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
cfg = get_config("llama3.2-3b").reduced()
model = build_model(cfg)
params, _ = split_params(model.init_params(jax.random.key(0)))
rng = np.random.default_rng(0)
prompts = [rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
           for _ in range(3)]
tenants = [TenantSpec("gold", 2), TenantSpec("free", 0)]

def drive(eng):
    sched = RequestScheduler(eng, tenants)
    rids = [sched.submit("free", prompts[0], max_new_tokens=8),
            sched.submit("free", prompts[1], max_new_tokens=8)]
    sched.step(); sched.step()
    rids.append(sched.submit("gold", prompts[2], max_new_tokens=8))
    sched.drain(max_rounds=120)
    return ([sched.requests[r].tokens_out for r in rids],
            sum(q.preemptions for q in sched.requests.values()),
            max(r.launches for r in sched.reports))

roomy = ServingEngine(cfg, params, mesh=mesh, max_seqs=8,
                      max_blocks_per_seq=8, num_slabs=4,
                      max_admit_pages=8, double_buffer=True)
ref_tokens, ref_pre, _ = drive(roomy)
tight = ServingEngine(cfg, params, mesh=mesh, max_seqs=2,
                      max_blocks_per_seq=8, num_slabs=2,
                      max_admit_pages=8, double_buffer=True, spill_pages=8)
tokens, pre, launches = drive(tight)
print("RESULTS:" + json.dumps({
    "tokens_match": tokens == ref_tokens,
    "preempted": int(pre),
    "ref_preempted": int(ref_pre),
    "max_launches": int(launches),
}))
"""


@pytest.mark.mesh
@pytest.mark.slow
def test_scheduler_preemption_parity_mesh(tmp_path):
    """8 host devices, (2, 4) mesh: demote/resume cross-pool copies ride
    the collective fused launches, and the preempted run's greedy tokens
    still match the roomy never-preempting twin bitwise."""
    res = run_device_subprocess(MESH_SCHED_CHILD, tmp_path=tmp_path)
    assert res["tokens_match"], res
    assert res["preempted"] > 0 and res["ref_preempted"] == 0, res
    assert res["max_launches"] <= 1, res
