"""Optimizer, schedule, checkpoint, data, and compression substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data import make_batch
from repro.optim import (apply_updates, clip_by_global_norm,
                         cosine_schedule, init_state)
from repro.optim.compress import init_error_state


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    """One step vs a hand-rolled numpy AdamW (no decay params excluded)."""
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=100,
                       weight_decay=0.1, grad_clip=1e9)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    grads = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    state = init_state(params)
    new_p, new_state, m = apply_updates(params, grads, state, tcfg)

    g = np.asarray(grads["w"])
    lr = float(cosine_schedule(tcfg, jnp.float32(1)))
    m1 = 0.1 * g
    v1 = 0.05 * g * g
    mh = m1 / (1 - 0.9)
    vh = v1 / (1 - 0.95)
    delta = mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.asarray(params["w"])
    expect = np.asarray(params["w"]) - lr * delta
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_state.step) == 1


def test_no_decay_for_norm_and_bias_params():
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, weight_decay=1.0,
                       grad_clip=1e9)
    params = {"layer": {"norm": jnp.ones((4,)), "w": jnp.ones((4,))}}
    grads = {"layer": {"norm": jnp.zeros((4,)), "w": jnp.zeros((4,))}}
    new_p, _, _ = apply_updates(params, grads, init_state(params), tcfg)
    # zero grad + decay: only 'w' should shrink
    assert float(jnp.abs(new_p["layer"]["norm"] - 1).max()) < 1e-6
    assert float(new_p["layer"]["w"][0]) < 1.0


def test_grad_clip():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 30


def test_cosine_schedule_shape():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(tcfg, jnp.float32(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]        # cosine decays
    assert lrs[4] >= 0.1 * 0.99              # floor at 10%


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def _state_tree(key):
    k1, k2 = jax.random.split(key)
    return {"params": {"w": jax.random.normal(k1, (8, 8)),
                       "b": jax.random.normal(k2, (8,))},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    state = _state_tree(jax.random.key(0))
    ckpt.save(5, state)
    restored, step = ckpt.restore(state)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    state = _state_tree(jax.random.key(1))
    for s in (1, 2, 3, 4):
        ckpt.save(s, state)
    ckpt.wait()
    assert ckpt.steps() == [3, 4]
    _, step = ckpt.restore(state)
    assert step == 4


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_save=False)
    state = _state_tree(jax.random.key(2))
    ckpt.save(1, state)
    # a stale tmp dir from a "crashed" writer must be invisible
    os.makedirs(tmp_path / "step_9.tmp")
    assert ckpt.steps() == [1]
    assert ckpt.latest_step() == 1


# ---------------------------------------------------------------------------
# compression (error feedback)
# ---------------------------------------------------------------------------

def test_bf16_error_feedback_is_unbiased_over_time():
    """Sum of compressed values + final residual == sum of true values."""
    from repro.optim.compress import compress_psum_bf16
    # dp=1 psum is identity — error-feedback algebra still exercised
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal((64,)) * 1e-3) for _ in
              range(20)]
    err = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    for g in g_true:
        (sent,), (err,) = compress_psum_bf16((g,), (err,), (), 1)
        total_sent = total_sent + sent
    total_true = sum(np.asarray(g, np.float64) for g in g_true)
    drift = np.abs(np.asarray(total_sent + err, np.float64) - total_true)
    assert drift.max() < 1e-5


def test_int8_quantization_bounded_error():
    from repro.optim.compress import compress_psum_int8
    g = jnp.asarray(np.random.default_rng(1).standard_normal((128,)))
    err0 = jnp.zeros((128,))
    (out,), (err,) = compress_psum_int8((g,), (err0,), (), 1)
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert float(jnp.abs(out - g).max()) <= scale * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(out + err), np.asarray(g),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_packed_batches_have_eos_and_valid_ranges():
    from repro.configs import get_config
    cfg = get_config("yi-6b").reduced()
    b = make_batch(cfg, 4, 256, step=3)
    assert b["tokens"].shape == (4, 256)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size
    assert (b["tokens"] == 1).any()  # EOS separators present
    # labels are next-token shifted
    full = make_batch(cfg, 4, 256, step=3)
    np.testing.assert_array_equal(b["labels"][:, :-1], full["tokens"][:, 1:])
