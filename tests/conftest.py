import os

# Tests run on the single real CPU device (the dry-run alone forces 512
# placeholder devices).  Multi-device semantics are tested via subprocess in
# test_multidevice.py.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Tests must not depend on a committed configs/tuned/ profile: engines
# would silently resolve overlap/ring_capacity from it and results would
# change whenever the autotuner is re-run.  test_obs.py re-enables
# loading per-test via monkeypatched REPRO_NO_TUNED/REPRO_TUNED_DIR.
os.environ.setdefault("REPRO_NO_TUNED", "1")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
