"""Fused command-queue dispatch: parity with the seed per-op path, launch
counting, bucketed padding, hazard guards, and the batched CoW-cache step.

Parity is checked at two layers:
* kernel: ``fused_dispatch_pallas`` (interpret=True — the actual kernel
  body on CPU) vs the jnp reference vs the seed per-op oracles;
* engine: ``use_fused=True`` vs ``use_fused=False`` (the seed fan-out,
  byte-for-byte), with the fused engine optionally forced through the
  interpret-mode kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BUCKETS, BlockRef, PagedCoWCache, RowCloneEngine,
                        SubarrayAllocator, bucket_size)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels import fused_dispatch as fd
from repro.kernels.fused_dispatch import (OP_AND, OP_BASELINE_COPY,
                                          OP_CROSS_POOL_COPY, OP_FPM_COPY,
                                          OP_NOP, OP_NOT, OP_OR, OP_PSM_COPY,
                                          OP_ZERO_INIT, add_launch_hook,
                                          fused_dispatch_pallas,
                                          pack_bitwise_src,
                                          remove_launch_hook)


class LaunchRecorder:
    """The launch-count hook: records (n_commands, n_pools, mechanism)."""

    def __init__(self):
        self.events = []

    def __call__(self, n, p, mech):
        self.events.append((n, p, mech))

    def __enter__(self):
        add_launch_hook(self)
        return self

    def __exit__(self, *exc):
        remove_launch_hook(self)


def _mk_pools(nblk, block_axis, seed=0, dtype=jnp.float32):
    shape = (nblk, 4, 8) if block_axis == 0 else (3, nblk, 4, 8)
    k = jax.random.normal(jax.random.key(seed), shape).astype(dtype)
    v = jax.random.normal(jax.random.key(seed + 1), shape).astype(dtype)
    zb = jnp.zeros((1, 4, 8), dtype)
    return (k, v), (zb, zb)


def _mixed_cmds(nblk, n, rng):
    """n mixed commands with disjoint sources/destinations (the flush
    contract the CommandQueue guarantees)."""
    ids = rng.permutation(nblk)
    half = nblk // 2
    srcs, dsts = ids[:half], ids[half:]
    ops = [OP_FPM_COPY, OP_PSM_COPY, OP_BASELINE_COPY, OP_ZERO_INIT]
    rows = []
    for i in range(n):
        op = ops[i % len(ops)]
        s = -1 if op == OP_ZERO_INIT else int(srcs[i % half])
        rows.append((op, s, int(dsts[i % (nblk - half)])))
    return rows


# ---------------------------------------------------------------------------
# kernel-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_axis", [0, 1])
@pytest.mark.parametrize("n_cmds", [3, 8, 20])
def test_fused_kernel_matches_seed_per_op_path(block_axis, n_cmds):
    """One fused launch == the seed's per-mechanism oracles applied to the
    same (disjoint) command set, bitwise."""
    nblk = 48
    rng = np.random.default_rng(block_axis * 100 + n_cmds)
    pools, zbs = _mk_pools(nblk, block_axis)
    rows = _mixed_cmds(nblk, n_cmds, rng)
    table = np.full((bucket_size(n_cmds), 3), OP_NOP, np.int32)
    table[:n_cmds] = rows
    cmds = jnp.asarray(table)

    out_k = fused_dispatch_pallas([p.copy() for p in pools], zbs, cmds,
                                  block_axis=block_axis, interpret=True)
    out_r = kref.fused_dispatch(pools, zbs, cmds, block_axis=block_axis)

    # seed path: per-mechanism per-pool
    copy_pairs = [(s, d) for op, s, d in rows if op != OP_ZERO_INIT]
    zero_ids = [d for op, _, d in rows if op == OP_ZERO_INIT]
    cp = jnp.asarray(np.asarray(copy_pairs, np.int32))
    zi = jnp.asarray(np.asarray(zero_ids, np.int32))
    seed_out = []
    for p, zb in zip(pools, zbs):
        if block_axis == 0:
            p = kref.fpm_copy(p, cp[:, 0], cp[:, 1])
            p = kref.zero_init(p, zi)
        else:
            rows_g = p[:, jnp.clip(cp[:, 0], 0, nblk - 1)]
            p = p.at[:, cp[:, 1]].set(rows_g)
            fill = jnp.zeros((p.shape[0], zi.shape[0]) + p.shape[2:],
                             p.dtype)
            p = p.at[:, zi].set(fill)
        seed_out.append(p)

    for a, b, c in zip(out_k, out_r, seed_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("block_axis", [0, 1])
def test_fused_kernel_cross_pool(block_axis):
    """CROSS_POOL_COPY moves k[s] into v[d] (stacked global ids)."""
    nblk = 16
    pools, zbs = _mk_pools(nblk, block_axis, seed=7)
    cmds = jnp.asarray(np.array(
        [[OP_CROSS_POOL_COPY, 0 * nblk + 4, 1 * nblk + 13],
         [OP_CROSS_POOL_COPY, 1 * nblk + 2, 0 * nblk + 9],
         [OP_NOP, -1, -1], [OP_NOP, -1, -1],
         [OP_NOP, -1, -1], [OP_NOP, -1, -1],
         [OP_NOP, -1, -1], [OP_NOP, -1, -1]], np.int32))
    out = fused_dispatch_pallas([p.copy() for p in pools], zbs, cmds,
                                block_axis=block_axis, interpret=True)
    k, v = pools
    sl = (slice(None), 4) if block_axis == 1 else (4,)
    dl = (slice(None), 13) if block_axis == 1 else (13,)
    np.testing.assert_array_equal(np.asarray(out[1])[dl if block_axis == 0
                                                     else (slice(None), 13)],
                                  np.asarray(k)[sl])
    if block_axis == 0:
        np.testing.assert_array_equal(np.asarray(out[0])[9],
                                      np.asarray(v)[2])
    else:
        np.testing.assert_array_equal(np.asarray(out[0])[:, 9],
                                      np.asarray(v)[:, 2])


@pytest.mark.parametrize("block_axis", [0, 1])
def test_fused_kernel_bitwise(block_axis):
    """OP_AND/OP_OR/OP_NOT rows in one table: srcB rides packed in the
    src field (``src = a_gid * total + b_gid`` over the stacked global-id
    space) and results match a numpy uint32 oracle to the exact bit, on
    both the interpret-mode kernel body and the jnp reference."""
    nblk = 16
    total = 2 * nblk
    pools, zbs = _mk_pools(nblk, block_axis, seed=11)
    pk = lambda a, b: pack_bitwise_src(a, b, total)
    rows = [
        [OP_AND, pk(0 * nblk + 1, 1 * nblk + 2), 0 * nblk + 9],
        [OP_OR, pk(1 * nblk + 3, 0 * nblk + 4), 1 * nblk + 10],
        [OP_NOT, pk(0 * nblk + 5, 0 * nblk + 5), 1 * nblk + 11],
        [OP_NOT, pk(1 * nblk + 6, 1 * nblk + 6), 0 * nblk + 12],
    ]
    table = np.full((8, 3), OP_NOP, np.int32)
    table[:len(rows)] = rows
    cmds = jnp.asarray(table)
    out_k = fused_dispatch_pallas([p.copy() for p in pools], zbs, cmds,
                                  block_axis=block_axis, interpret=True)
    out_r = kref.fused_dispatch(pools, zbs, cmds, block_axis=block_axis)
    k, v = (np.asarray(p) for p in pools)
    sel = (lambda arr, b: arr[b]) if block_axis == 0 \
        else (lambda arr, b: arr[:, b])
    u = lambda x: np.ascontiguousarray(x).view(np.uint32)
    want = {
        ("k", 9): u(sel(k, 1)) & u(sel(v, 2)),
        ("v", 10): u(sel(v, 3)) | u(sel(k, 4)),
        ("v", 11): ~u(sel(k, 5)),
        ("k", 12): ~u(sel(v, 6)),
    }
    for out in (out_k, out_r):
        got = {"k": np.asarray(out[0]), "v": np.asarray(out[1])}
        for (pool, b), bits in want.items():
            np.testing.assert_array_equal(u(sel(got[pool], b)), bits,
                                          err_msg=f"{pool}[{b}]")


# ---------------------------------------------------------------------------
# engine-level parity: fused flush vs seed fan-out
# ---------------------------------------------------------------------------

def _mk_engine(nblk=64, nslabs=4, block_axis=0, use_fused=True, seed=0,
               **kw):
    alloc = SubarrayAllocator(nblk, nslabs, reserved_zero_per_slab=1)
    shape = (nblk, 8, 2, 16) if block_axis == 0 else (2, nblk, 8, 16)
    pools = {
        "k": jax.random.normal(jax.random.key(seed), shape),
        "v": jax.random.normal(jax.random.key(seed + 1), shape),
    }
    eng = RowCloneEngine(pools, alloc, mesh=None, max_requests=256,
                         block_axis=block_axis, use_fused=use_fused, **kw)
    return eng


def _drive(eng, rng, n_copies, n_zeros):
    """Issue one deferred batch of mixed copies + zero-inits and flush."""
    nblk = eng.num_blocks
    ids = rng.permutation(nblk)
    ids = ids[~np.isin(ids, eng.alloc.zero_rows)]
    srcs = [int(b) for b in ids[:n_copies]]
    dsts = [int(b) for b in ids[n_copies:2 * n_copies]]
    zeros = [int(b) for b in ids[2 * n_copies:2 * n_copies + n_zeros]]
    eng.alloc.mark_written(srcs)
    with eng.batch():
        eng.memcopy(list(zip(srcs, dsts)))
        eng.materialize_zeros(zeros)


@pytest.mark.parametrize("block_axis", [0, 1])
@pytest.mark.parametrize("interpret_kernel", [False, True])
def test_engine_fused_matches_seed_fanout(block_axis, interpret_kernel,
                                          monkeypatch):
    """Mixed FPM/PSM/zero flush: fused engine pools are bitwise identical
    to the seed per-op fan-out engine, via both the jnp reference and the
    interpret-mode kernel body."""
    if interpret_kernel:
        orig = kops.fused_dispatch
        monkeypatch.setattr(
            kops, "fused_dispatch",
            lambda *a, **kw: orig(*a, **{**kw, "use_pallas": True}))
    rng = np.random.default_rng(42)
    fused = _mk_engine(block_axis=block_axis, use_fused=True)
    legacy = _mk_engine(block_axis=block_axis, use_fused=False)
    for eng in (fused, legacy):
        _drive(eng, np.random.default_rng(7), n_copies=9, n_zeros=4)
    assert fused.stats.fpm_copies == legacy.stats.fpm_copies
    assert fused.stats.psm_copies == legacy.stats.psm_copies
    for name in fused.pools:
        np.testing.assert_array_equal(np.asarray(fused.pools[name]),
                                      np.asarray(legacy.pools[name]))
    assert fused.stats.launches == 1
    assert legacy.stats.launches > 1  # the fan-out this PR removes


@pytest.mark.parametrize("n", [1, 5, 8, 9, 30, 127, 200])
def test_bucketed_padding(n):
    """Tables pad to the smallest power-of-two bucket, not a fixed 256."""
    eng = _mk_engine(nblk=512, nslabs=4)
    rng = np.random.default_rng(n)
    with LaunchRecorder() as rec:
        _drive(eng, rng, n_copies=n, n_zeros=0)
    assert len(rec.events) == 1
    assert rec.events[0][0] == bucket_size(n)
    assert rec.events[0][1] == 2  # k and v moved in the same launch


def test_overflow_chunks_instead_of_raising():
    """> top bucket commands drain in ceil(n/512) launches (seed raised
    ValueError on the mesh path and silently truncated on one device)."""
    nblk = 2048
    eng = _mk_engine(nblk=nblk, nslabs=4)
    srcs = list(range(0, 600))
    dsts = list(range(1024, 1624))
    eng.alloc.mark_written(srcs)
    with LaunchRecorder() as rec:
        with eng.batch():
            eng.memcopy(list(zip(srcs, dsts)))
    assert len(rec.events) == 2
    assert rec.events[0][0] == BUCKETS[-1]
    assert rec.events[1][0] == bucket_size(600 - BUCKETS[-1])
    # spot-check content actually moved
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][1623]),
                                  np.asarray(eng.pools["k"][599]))


def test_hazard_guard_read_after_write_autoflushes():
    """b -> c after a -> b in one deferred batch must see a's data in b:
    the queue flushes the first table before accepting the dependent
    command."""
    eng = _mk_engine()
    a, b, c = 5, 9, 13
    eng.alloc.mark_written([a, b, c])
    want_b = np.asarray(eng.pools["k"][a])
    with eng.batch():
        eng.memcopy([(a, b)])
        assert len(eng.queue) == 1
        eng.memcopy([(b, c)])       # hazard: src b is a pending dst
    assert eng.queue.stats.hazard_flushes == 1
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][b]), want_b)
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][c]), want_b)


def test_memcopy_chained_through_lazy_zero_dst():
    """(a, b), (b, c) in ONE call where b was lazy-zero: b must be treated
    as real data once the a->b copy is enqueued, so c receives a's bytes —
    not the stale ZI alias (regression: mark_written ran after the loop)."""
    eng = _mk_engine()
    a, b, c = 5, 9, 13
    eng.alloc.mark_written([a])
    eng.alloc.mark_zero([b])
    want = np.asarray(eng.pools["k"][a])
    eng.memcopy([(a, b), (b, c)])
    assert not eng.alloc.is_zero[c]
    assert eng.stats.alias_copies == 0
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][c]), want)


def test_memcopy_cross_keeps_zi_metadata_sound():
    """Cross-pool copies must not leave stale ZI bits: a lazy-zero source
    materializes first (its physical bytes are garbage), and the dst loses
    any lazy-zero marking so later copies don't alias real data as zero."""
    eng = _mk_engine(seed=21)
    s, d, e = 5, 9, 13
    eng.alloc.mark_zero([s, d])
    eng.memcopy_cross([(BlockRef("k", s), BlockRef("v", d))])
    # lazy-zero source -> dst receives zeros, not the stale pool bytes
    assert float(jnp.abs(eng.pools["v"][d]).max()) == 0.0
    assert not eng.alloc.is_zero[d]
    # a later copy from d must move bytes, not take the alias fast path
    eng.memcopy([(d, e)])
    assert eng.stats.alias_copies == 0
    assert not eng.alloc.is_zero[e]


def test_engine_cross_pool_copy_matches_seed_cross():
    eng = _mk_engine(seed=3)
    ref = kref.fpm_copy_cross(eng.pools["v"], eng.pools["k"],
                              jnp.asarray([2, 7], jnp.int32),
                              jnp.asarray([11, 23], jnp.int32))
    with LaunchRecorder() as rec:
        eng.memcopy_cross([(BlockRef("k", 2), BlockRef("v", 11)),
                           (BlockRef("k", 7), BlockRef("v", 23))])
    assert [e[2] for e in rec.events] == ["fused"]
    np.testing.assert_array_equal(np.asarray(eng.pools["v"]),
                                  np.asarray(ref))
    assert eng.stats.cross_pool_copies == 2


def _ubits(x):
    """Uint32 bit view for exact-bit comparison of bitwise results."""
    return np.ascontiguousarray(np.asarray(x)).view(np.uint32)


def test_engine_bitwise_matches_seed_fanout_one_launch():
    """A mixed AND/OR/NOT + copy batch: the fused engine drains it as ONE
    launch, the seed fan-out takes several, and the two leave
    bit-identical pools — stats agree on both paths."""
    fused = _mk_engine(seed=21, use_fused=True)
    legacy = _mk_engine(seed=21, use_fused=False)
    recs = {}
    for eng in (fused, legacy):
        eng.alloc.mark_written([1, 2, 3, 8])
        with LaunchRecorder() as rec, eng.batch():
            eng.memcopy([(8, 40)])
            eng.memand([(1, 2, 30)])                 # int fan-out: k AND v
            eng.memor([(BlockRef("k", 2), BlockRef("v", 3),
                        BlockRef("v", 31))])         # cross-pool BlockRefs
            eng.memnot([(3, 32)])
        recs[eng.use_fused] = rec.events
        # int fan-out enqueues one row per primary pool: 2 + 1 + 2
        assert eng.stats.bitwise_ops == 5
        assert eng.stats.bytes_bitwise > 0
    assert [e[2] for e in recs[True]] == ["fused"]
    assert len(recs[False]) > 1                      # the fan-out removed
    assert fused.stats.bytes_bitwise == legacy.stats.bytes_bitwise
    np.testing.assert_array_equal(
        _ubits(fused.pools["k"][30]),
        _ubits(fused.pools["k"][1]) & _ubits(fused.pools["k"][2]))
    np.testing.assert_array_equal(
        _ubits(fused.pools["v"][31]),
        _ubits(fused.pools["k"][2]) | _ubits(fused.pools["v"][3]))
    np.testing.assert_array_equal(_ubits(fused.pools["v"][32]),
                                  ~_ubits(fused.pools["v"][3]))
    for name in fused.pools:
        np.testing.assert_array_equal(_ubits(fused.pools[name]),
                                      _ubits(legacy.pools[name]),
                                      err_msg=name)


@pytest.mark.parametrize("use_fused", [True, False])
def test_engine_bitwise_in_place_dst_is_source(use_fused):
    """dst == srcA and dst == srcB within one row are legal in-place
    updates: sources are gathered before the scatter lands on every
    dispatch path."""
    eng = _mk_engine(seed=23, use_fused=use_fused)
    eng.alloc.mark_written([4, 5, 6])
    old4 = _ubits(eng.pools["k"][4]).copy()
    old5 = _ubits(eng.pools["k"][5]).copy()
    old6 = _ubits(eng.pools["k"][6]).copy()
    with eng.batch():
        eng.memand([(4, 5, 4)])          # dst == srcA
    with eng.batch():
        eng.memor([(6, 5, 5)])           # dst == srcB
    with eng.batch():
        eng.memnot([(6, 6)])             # dst == the single source
    np.testing.assert_array_equal(_ubits(eng.pools["k"][4]), old4 & old5)
    np.testing.assert_array_equal(_ubits(eng.pools["k"][5]), old6 | old5)
    np.testing.assert_array_equal(_ubits(eng.pools["k"][6]), ~old6)


def test_membitwise_rejects_unpackable_pool_group():
    """srcB packing must stay within int32 (``a_gid * total + b_gid``):
    an engine whose PoolGroup exceeds the 46340-block bound still
    constructs and copies fine, but bitwise verbs raise a descriptive
    ValueError instead of silently wrapping the packed id."""
    nblk = 46341                          # total 46341 -> 46341^2 > int32
    alloc = SubarrayAllocator(nblk, 1)
    pools = {"k": jnp.zeros((nblk, 1, 2), jnp.float32)}
    eng = RowCloneEngine(pools, alloc, max_requests=8)
    eng.alloc.mark_written([1, 2])
    eng.memcopy([(1, 3)])                 # plain opcodes stay legal
    with pytest.raises(ValueError, match="46340"):
        eng.memand([(1, 2, 4)])


# ---------------------------------------------------------------------------
# the acceptance scenario: one launch per flush for a mixed {"k","v"} batch
# ---------------------------------------------------------------------------

def test_mixed_batch_one_launch_per_flush():
    """N copies + zero-inits over a {"k","v"} pool pair: exactly ONE kernel
    launch at the flush boundary (the seed issued up to one per mechanism
    per pool)."""
    eng = _mk_engine(nblk=64, nslabs=4)
    srcs = [1, 2, 3, 17, 18]          # slabs 0 and 1 -> FPM + PSM mix
    dsts = [4, 5, 33, 49, 50]
    zeros = [6, 7, 21]
    eng.alloc.mark_written(srcs)
    with LaunchRecorder() as rec:
        with eng.batch():
            eng.memcopy(list(zip(srcs, dsts)))
            eng.materialize_zeros(zeros)
        assert len(rec.events) == 1
        assert rec.events[0][1] == 2
        assert rec.events[0][2] == "fused"
    counts = {"fpm": eng.stats.fpm_copies, "psm": eng.stats.psm_copies}
    assert counts["fpm"] > 0 and counts["psm"] > 0
    assert eng.stats.zero_materialized == 3
    assert eng.stats.launches == 1


def test_cow_cache_batched_step_single_launch():
    """A decode round over forked sequences: every CoW split + tail init in
    ONE launch, with results identical to the per-sequence path."""
    def build():
        eng = _mk_engine(nblk=64, nslabs=4, seed=11)
        cache = PagedCoWCache(eng, page=8, max_blocks_per_seq=8, max_seqs=8)
        sid = cache.new_sequence(prompt_len=12)
        eng.alloc.mark_written(cache.blocks_of(sid))
        kids = cache.fork(sid, 2)
        return eng, cache, [sid] + kids

    eng_a, cache_a, seqs_a = build()
    with LaunchRecorder() as rec:
        out_a = cache_a.append_tokens(seqs_a)
    fused_events = [e for e in rec.events if e[2] == "fused"]
    assert len(fused_events) == 1

    eng_b, cache_b, seqs_b = build()
    out_b = [cache_b.append_token(s) for s in seqs_b]
    assert [o[1] for o in out_a] == [o[1] for o in out_b]
    for name in eng_a.pools:
        np.testing.assert_array_equal(np.asarray(eng_a.pools[name]),
                                      np.asarray(eng_b.pools[name]))


@pytest.mark.parametrize("use_fused", [True, False])
def test_war_ordering_fused_and_legacy_agree(use_fused):
    """Write-after-read inside one table: (PSM, b->nb) then (FPM, c->b) is
    permitted by the hazard guard (b is only a pending *source*).  Both
    drains must apply it in enqueue order — nb gets b's OLD data, b gets
    c's (regression: legacy grouped the whole table by opcode, running the
    FPM group before the PSM group)."""
    eng = _mk_engine(use_fused=use_fused, seed=17)
    b, nb = 3, 33          # slabs 0 and 2 -> PSM
    c = 7                  # slab 0, same slab as b -> FPM
    eng.alloc.mark_written([b, c])
    old_b = np.asarray(eng.pools["k"][b])
    old_c = np.asarray(eng.pools["k"][c])
    with eng.batch():
        counts1 = eng.memcopy([(b, nb)])
        counts2 = eng.memcopy([(c, b)])
    assert counts1["psm"] == 1 and counts2["fpm"] == 1
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][nb]), old_b)
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][b]), old_c)


@pytest.mark.parametrize("use_fused", [True, False])
def test_cross_pool_war_interleaved_directions(use_fused):
    """Interleaved opposite-direction cross-pool copies with a
    write-after-read: k1->v2, v5->k6, k7->v5 all pass the hazard guard
    (v5 is only a pending *source*), so k6 must get v5's OLD bytes
    (regression: _legacy_cross grouped by pool pair, running k7->v5
    before v5->k6)."""
    eng = _mk_engine(seed=29, use_fused=use_fused)
    eng.alloc.mark_written([1, 5, 7])
    old_v5 = np.asarray(eng.pools["v"][5])
    with eng.batch():
        eng.memcopy_cross([(BlockRef("k", 1), BlockRef("v", 2))])
        eng.memcopy_cross([(BlockRef("v", 5), BlockRef("k", 6))])
        eng.memcopy_cross([(BlockRef("k", 7), BlockRef("v", 5))])
    np.testing.assert_array_equal(np.asarray(eng.pools["k"][6]), old_v5)
    np.testing.assert_array_equal(np.asarray(eng.pools["v"][5]),
                                  np.asarray(eng.pools["k"][7]))
    np.testing.assert_array_equal(np.asarray(eng.pools["v"][2]),
                                  np.asarray(eng.pools["k"][1]))


def test_legacy_cross_pool_axis1():
    """block_axis=1 cross-pool copies on the legacy path must index the
    block axis, not the layer axis (regression: _legacy_cross had no
    axis-1 branch)."""
    eng = _mk_engine(block_axis=1, use_fused=False, seed=23)
    eng.alloc.mark_written([5])
    want = np.asarray(eng.pools["k"][:, 5])
    # 40 >= L: would hit the layer axis if misindexed (axis-0 gather)
    eng.memcopy_cross([(BlockRef("k", 5), BlockRef("v", 40))])
    np.testing.assert_array_equal(np.asarray(eng.pools["v"][:, 40]), want)


@pytest.mark.mesh
def test_engine_mesh_dispatch_subprocess():
    """Multi-device mesh: a flush drains as ONE shard_map'd fused launch
    over per-slab sub-tables (a 1-D 4-device mesh here; the seed's per-slab
    fan-out table would overflow at >max_requests same-slab pairs and
    raise)."""
    import textwrap
    from _meshproc import run_device_subprocess
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import RowCloneEngine, SubarrayAllocator
        mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("model",))
        nblk = 32
        alloc = SubarrayAllocator(nblk, 4)
        pools = {"k": jax.random.normal(jax.random.key(0), (nblk, 4, 8)),
                 "v": jax.random.normal(jax.random.key(1), (nblk, 4, 8))}
        want = {n: np.asarray(p) for n, p in pools.items()}
        eng = RowCloneEngine(pools, alloc, mesh=mesh, max_requests=4)
        # 6 same-slab pairs; slab 0 holds 4 of them
        pairs = [(1, 2), (3, 4), (5, 6), (7, 1), (9, 10), (11, 12)]
        alloc.mark_written([s for s, _ in pairs])
        counts = eng.memcopy(pairs)
        assert counts == {"fpm": 6, "psm": 0, "baseline": 0}, counts
        assert eng.stats.launches == 1, eng.stats.launches
        for n in want:
            ref = want[n].copy()
            for s, d in pairs:
                ref[d] = want[n][s]
            np.testing.assert_allclose(np.asarray(eng.pools[n]), ref)
        print("OK")
    """)
    out = run_device_subprocess(script, marker=None, timeout=600)
    assert "OK" in out.stdout, out.stdout


def test_fork_eager_copy_clones_blocks_one_launch():
    eng = _mk_engine(nblk=64, nslabs=4, seed=5)
    cache = PagedCoWCache(eng, page=8, max_blocks_per_seq=8, max_seqs=8)
    sid = cache.new_sequence(prompt_len=16)
    blocks = cache.blocks_of(sid)
    eng.alloc.mark_written(blocks)
    with LaunchRecorder() as rec:
        kid, = cache.fork(sid, 1, eager_copy=True)
    assert len(rec.events) == 1
    kb = cache.blocks_of(kid)
    assert kb != blocks
    for old, new in zip(blocks, kb):
        assert not eng.alloc.is_shared(old)
        np.testing.assert_array_equal(np.asarray(eng.pools["k"][new]),
                                      np.asarray(eng.pools["k"][old]))
